"""multipod: prove the ('pod','data') sharding domain pays off across the DCN.

Paper Fig. 7/8 analogue on the compiled artifact, two halves:

1. **HLO ground truth** — lower + compile the train step and the decode
   step on multi-pod meshes, scan the compiled HLO with
   ``core.hlo_analysis.collective_stats`` + ``core.topology.device_pod_map``
   (exact per-edge classification for collective-permutes, ring-decomposed
   accounting for XLA's group collectives), and assert the locality paths
   move STRICTLY fewer non-local (inter-pod) bytes AND messages than the
   flat XLA paths:

   * train FSDP on the 2×16 ('pod','data') mesh: the locality-aware Bruck
     gather + its reduce-scatter transpose (grad_sync="locality",
     fsdp_axes=('pod','data')) vs GSPMD's flat all-gather/reduce-scatter
     (grad_sync="xla") over the same composite layout;
   * serve decode on the production 2×16×16 mesh: the hierarchical
     logsumexp cache-combine (combine="locality", sequence-parallel cache
     over ('pod','data')) vs GSPMD's implicit flat combine
     (combine="xla").

   * serve cache migration on a 2×4 ('pod','data') mesh: the scheduler's
     cross-pod KV-slab replication through the explicit ``cache_migrate``
     collective (locality-Bruck schedule inside a manual shard_map region)
     vs GSPMD's implicit flat resharding of the same donor-layout input —
     plus a runtime half that forces four real migrations through the
     continuous scheduler and requires every comm-ledger label (prefill,
     migrate, decode) to reconcile predicted == actual exactly.

   * MoE expert dispatch ("moe-multipod") on 2×8 and 3×8 ('pod','data')
     meshes: the qwen2-moe train step with ``moe_dispatch="locality"``
     (two-tier ``locality_all_to_all`` + token transport — the batch block
     crosses the DCN once per destination pod and only int32 slot tables
     ride the exchange) vs ``moe_dispatch="xla"`` (flat slot all-to-all).
     Gated exactly like the other cells — strictly fewer inter-pod bytes
     AND messages — plus a structural check that the locality step lowers
     without a single grouped all-to-all op (DESIGN.md §12).

   * BOTH halves again on THREE-pod meshes (3×8 ('pod','data')) — the
     non-power region count that exercises Algorithm 2's allgatherv
     adaptation (partial final-round payloads; Bruck-transpose grad
     reduce-scatter; fold/unfold max phase — DESIGN.md §7). Before this
     adaptation the locality paths silently fell back to flat psum on
     q = 3, so this cell is the CI gate that the locality claim holds on
     the mesh shapes real fleets actually have.

2. **Numerics** — on a 2×4 ('pod','data') mesh (8 host devices), the
   pod-aware layouts must agree with the legacy 'data'-only layouts on the
   same device count: train loss bitwise-identical and params equal to
   fp32 resolution (the grad reduction ASSOCIATES differently across
   layouts — two-tier RS vs intra-pod RS + pod allreduce — so the last-ulp
   pattern differs while every forward value is bitwise-identical; the
   recorded ``params_bitwise`` flag shows what this host produced), and
   greedy decode tokens exactly equal across pod-aware locality, pod-aware
   XLA, and data-only layouts. The same equivalences re-run on a 3×2 mesh
   (6 host devices) where the wrapped final Bruck round carries a genuine
   partial payload.

Writes ``BENCH_multipod.json``; any violated inequality fails the run.
"""
from __future__ import annotations

import json
import os

from .common import REPO, emit, run_multidevice, write_bench_json

OUT = os.path.join(REPO, "BENCH_multipod.json")

TRAIN_HLO_CODE = r"""
import json, dataclasses
import jax
from repro import configs
from repro.core.hlo_analysis import collective_stats
from repro.core.topology import device_pod_map
from repro.train.step import custom_batch_specs, make_train_step

mesh = jax.make_mesh((2, 16), ("pod", "data"))
jax.set_mesh(mesh)
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2)
bspec = custom_batch_specs(cfg, 32, 64)
pod_map = device_pod_map(mesh, ("pod",))
out = {"mesh": "2x16 (pod,data)", "n_devices": 32}
for name, kw in (("locality", dict(grad_sync="locality")),
                 ("flat_xla", dict(grad_sync="xla"))):
    art = make_train_step(cfg, mesh, fsdp=True, shape=bspec, donate=False,
                          **kw)
    assert art.fsdp_axes == ("pod", "data"), art.fsdp_axes
    hlo = art.step_fn.lower(art.abstract_state, bspec).compile().as_text()
    st = collective_stats(hlo, pod_map)
    out[name] = {
        "counts": dict(st.counts),
        "permute_edges_nonlocal": st.permute_edges_nonlocal,
        "permute_bytes_nonlocal": st.permute_bytes_nonlocal,
        "group_msgs_nonlocal": st.group_msgs_nonlocal,
        "group_bytes_nonlocal": st.group_bytes_nonlocal,
        "nonlocal_msgs": st.nonlocal_msgs,
        "nonlocal_bytes": st.nonlocal_bytes,
    }
print("JSON" + json.dumps(out))
"""

SERVE_HLO_CODE = r"""
import json, dataclasses
import jax, numpy as np
from repro import configs
from repro.core.hlo_analysis import collective_stats
from repro.core.topology import device_pod_map
from repro.launch.mesh import make_production_mesh
from repro.serve import ServeSpec
from repro.serve.engine import cache_specs, make_serve_fns

mesh = make_production_mesh(multi_pod=True)          # 2x16x16
jax.set_mesh(mesh)
# 16-KV-head variant so the KV heads (not head_dim) carry the model axis —
# the locality region's eligibility condition on a 16-wide TP axis
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2,
                          n_heads=32, n_kv_heads=16)
B, L = 1, 64                                          # seq-sharded over 32
art = make_serve_fns(cfg, mesh, ServeSpec(batch=B, cache_len=L,
                                          combine="locality"))
assert art.combine.algorithm == "locality", art.combine
assert art.combine.p == 32 and art.combine.p_local == 16, art.combine
assert art.seq_axes == ("pod", "data"), art.seq_axes
assert art.decode_fn_locality is not None, art
c_specs = cache_specs(cfg, B, L)
tok = jax.ShapeDtypeStruct((B, 1), np.int32)
pod_map = device_pod_map(mesh, ("pod",))
out = {"mesh": "2x16x16 (pod,data,model)", "n_devices": 512,
       "combine": art.combine.algorithm}
for name, fn in (("locality", art.decode_fn_locality),
                 ("flat_xla", art.decode_fn_xla)):
    hlo = fn.lower(art.abstract_params, c_specs, tok).compile().as_text()
    st = collective_stats(hlo, pod_map)
    out[name] = {
        "counts": dict(st.counts),
        "permute_edges_nonlocal": st.permute_edges_nonlocal,
        "permute_bytes_nonlocal": st.permute_bytes_nonlocal,
        "group_msgs_nonlocal": st.group_msgs_nonlocal,
        "group_bytes_nonlocal": st.group_bytes_nonlocal,
        "nonlocal_msgs": st.nonlocal_msgs,
        "nonlocal_bytes": st.nonlocal_bytes,
    }
print("JSON" + json.dumps(out))
"""

THREEPOD_HLO_CODE = r"""
import json, dataclasses
import jax, numpy as np
from repro import configs
from repro.core.hlo_analysis import collective_stats, op_payloads
from repro.core.topology import device_pod_map
from repro.serve import ServeSpec
from repro.serve.engine import cache_specs, make_serve_fns
from repro.train.step import custom_batch_specs, make_train_step

mesh = jax.make_mesh((3, 8), ("pod", "data"))
jax.set_mesh(mesh)
# dims divisible by the 3x8 composite span (24) so every FSDP leaf genuinely
# shards across all three pods — the allgatherv adaptation's domain
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2,
                          d_model=96, d_ff=192, vocab_size=384)
pod_map = device_pod_map(mesh, ("pod",))
out = {"mesh": "3x8 (pod,data)", "n_devices": 24}

# --- train FSDP: locality Algorithm-2 gather vs flat GSPMD ----------------
bspec = custom_batch_specs(cfg, 24, 64)
train = {}
for name, kw in (("locality", dict(grad_sync="locality")),
                 ("flat_xla", dict(grad_sync="xla"))):
    art = make_train_step(cfg, mesh, fsdp=True, shape=bspec, donate=False,
                          **kw)
    assert art.fsdp_axes == ("pod", "data"), art.fsdp_axes
    hlo = art.step_fn.lower(art.abstract_state, bspec).compile().as_text()
    st = collective_stats(hlo, pod_map)
    train[name] = {
        "counts": dict(st.counts),
        "permute_edges_nonlocal": st.permute_edges_nonlocal,
        "permute_bytes_nonlocal": st.permute_bytes_nonlocal,
        "group_msgs_nonlocal": st.group_msgs_nonlocal,
        "group_bytes_nonlocal": st.group_bytes_nonlocal,
        "nonlocal_msgs": st.nonlocal_msgs,
        "nonlocal_bytes": st.nonlocal_bytes,
    }
out["train_fsdp_3pod"] = train

# --- serve decode: hierarchical combine over q=3 pods vs flat GSPMD -------
B, L = 1, 48                                  # seq-sharded over 24
art = make_serve_fns(cfg, mesh, ServeSpec(batch=B, cache_len=L,
                                          combine="locality"))
assert art.combine.algorithm == "locality", art.combine
assert art.combine.p == 24 and art.combine.p_local == 8, art.combine
assert art.seq_axes == ("pod", "data"), art.seq_axes
assert art.decode_fn_locality is not None, art
c_specs = cache_specs(cfg, B, L)
tok = jax.ShapeDtypeStruct((B, 1), np.int32)
serve = {"combine": art.combine.algorithm}
for name, fn in (("locality", art.decode_fn_locality),
                 ("flat_xla", art.decode_fn_xla)):
    hlo = fn.lower(art.abstract_params, c_specs, tok).compile().as_text()
    st = collective_stats(hlo, pod_map)
    serve[name] = {
        "counts": dict(st.counts),
        "permute_edges_nonlocal": st.permute_edges_nonlocal,
        "permute_bytes_nonlocal": st.permute_bytes_nonlocal,
        "group_msgs_nonlocal": st.group_msgs_nonlocal,
        "group_bytes_nonlocal": st.group_bytes_nonlocal,
        "nonlocal_msgs": st.nonlocal_msgs,
        "nonlocal_bytes": st.nonlocal_bytes,
    }
    if name == "locality":
        # the non-power outer tiers must run Algorithm 2, not a psum
        # fallback: no add- or max-combiner all-reduce may survive in the
        # locality decode HLO (the flat path keeps GSPMD's implicit ones)
        assert not op_payloads(hlo, "all-reduce"), "psum fallback resurfaced"
out["serve_combine_3pod"] = serve
print("JSON" + json.dumps(out))
"""

NUMERICS3_CODE = r"""
import json, dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.data import SyntheticLM
from repro.optim import AdamW
from repro.serve import ServeSpec
from repro.serve.engine import Engine
from repro.train.step import custom_batch_specs, init_state, make_train_step

mesh = jax.make_mesh((3, 2), ("pod", "data"))
jax.set_mesh(mesh)
out = {"mesh": "3x2 (pod,data)", "n_devices": 6}
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2,
                          d_model=96, d_ff=192, vocab_size=384)
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=6,
                   seed=0)
bspec = custom_batch_specs(cfg, 6, 32)
# With q=3 the two layouts' grad reductions associate a THREE-term sum
# differently (two-tier Bruck-transpose RS vs intra-pod RS + pod
# allreduce), so grads agree only to fp32 ulp — and Adam's g/sqrt(g^2)
# normalization amplifies an ulp-level sign flip of a near-zero gradient
# into an lr-scale param difference (q=2 dodges this: a+b has one
# association). eps=1e-2 keeps the optimizer in its linear regime so the
# strict rtol below measures the gradient-sync equivalence itself.
opt = AdamW(eps=1e-2)
runs = {}
for name, axes in (("pod_data", "auto"), ("data_only", ("data",))):
    art = make_train_step(cfg, mesh, grad_sync="locality", fsdp=True,
                          fsdp_axes=axes, shape=bspec, donate=False,
                          optimizer=opt)
    state = init_state(cfg, mesh, art)
    batch = {k: jax.device_put(v, art.batch_shardings[k])
             for k, v in data.batch(0).items()}
    state2, metrics = art.step_fn(state, batch)
    runs[name] = (art, float(metrics["loss"]), state2)
assert runs["pod_data"][0].fsdp_axes == ("pod", "data")
loss_pod, loss_dat = runs["pod_data"][1], runs["data_only"][1]
assert loss_pod == loss_dat, (loss_pod, loss_dat)
max_rel, bitwise = 0.0, True
for x, y in zip(jax.tree.leaves(runs["pod_data"][2].params),
                jax.tree.leaves(runs["data_only"][2].params)):
    x, y = np.asarray(x, np.float64), np.asarray(y, np.float64)
    # grads differ at fp32 ulp (three-term association), so params land a
    # few ulp apart after clip/sqrt — measured max ~3e-7 abs on this cell
    np.testing.assert_allclose(x, y, rtol=5e-4, atol=1e-6)
    if not np.array_equal(x, y):
        bitwise = False
        denom = np.maximum(np.abs(y), 1e-30)
        max_rel = max(max_rel, float(np.max(np.abs(x - y) / denom)))
out["train"] = {"loss_bitwise_equal": True, "loss": loss_pod,
                "params_bitwise": bitwise, "params_max_rel_diff": max_rel}

from repro.models import transformer
params = transformer.init_params(jax.random.PRNGKey(0), cfg)
prompts = np.array([[3, 5, 7, 2, 9, 4]], dtype=np.int32)
NEW = 6
toks = {}
for name, kw in (("pod_loc", dict(combine="locality")),
                 ("pod_xla", dict(combine="xla")),
                 ("data_loc", dict(combine="locality", seq_axes=("data",)))):
    eng = Engine(cfg, mesh, params, ServeSpec(batch=1, cache_len=48, **kw))
    if name == "pod_loc":
        assert eng.combine.algorithm == "locality", eng.combine
        assert eng.combine.p == 6 and eng.combine.p_local == 2, eng.combine
        assert eng.art.decode_fn_locality is not None, eng.art
    toks[name] = eng.generate(prompts, NEW)
for a in ("pod_xla", "data_loc"):
    assert np.array_equal(toks["pod_loc"], toks[a]), (a, toks)
out["decode"] = {"tokens_exact_equal": True, "steps": NEW,
                 "tokens": toks["pod_loc"].tolist()}
print("JSON" + json.dumps(out))
"""

NUMERICS_CODE = r"""
import json, dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.data import SyntheticLM
from repro.serve import ServeSpec
from repro.serve.engine import Engine
from repro.train.step import custom_batch_specs, init_state, make_train_step

mesh = jax.make_mesh((2, 4), ("pod", "data"))
jax.set_mesh(mesh)
out = {"mesh": "2x4 (pod,data)", "n_devices": 8}

# --- train: pod-aware vs 'data'-only FSDP layout on the same mesh --------
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2)
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                   seed=0)
bspec = custom_batch_specs(cfg, 8, 64)
runs = {}
for name, axes in (("pod_data", "auto"), ("data_only", ("data",))):
    art = make_train_step(cfg, mesh, grad_sync="locality", fsdp=True,
                          fsdp_axes=axes, shape=bspec, donate=False)
    state = init_state(cfg, mesh, art)
    batch = {k: jax.device_put(v, art.batch_shardings[k])
             for k, v in data.batch(0).items()}
    state2, metrics = art.step_fn(state, batch)
    runs[name] = (art, float(metrics["loss"]), state2)
a_pod, a_dat = runs["pod_data"][0], runs["data_only"][0]
assert a_pod.fsdp_axes == ("pod", "data"), a_pod.fsdp_axes
assert a_dat.fsdp_axes == ("data",), a_dat.fsdp_axes
loss_pod, loss_dat = runs["pod_data"][1], runs["data_only"][1]
assert loss_pod == loss_dat, (loss_pod, loss_dat)   # forward is pure data
                                                    # movement: bitwise
pa = jax.tree.leaves(runs["pod_data"][2].params)
pb = jax.tree.leaves(runs["data_only"][2].params)
max_rel = 0.0
bitwise = True
for x, y in zip(pa, pb):
    x, y = np.asarray(x, np.float64), np.asarray(y, np.float64)
    np.testing.assert_allclose(x, y, rtol=2e-6, atol=1e-7)
    if not np.array_equal(x, y):
        bitwise = False
        denom = np.maximum(np.abs(y), 1e-30)
        max_rel = max(max_rel, float(np.max(np.abs(x - y) / denom)))
out["train"] = {"loss_bitwise_equal": True, "loss": loss_pod,
                "params_bitwise": bitwise, "params_max_rel_diff": max_rel}

# --- decode: pod-aware locality vs pod-aware xla vs 'data'-only ----------
from repro.models import transformer
params = transformer.init_params(jax.random.PRNGKey(0), cfg)
prompts = np.array([[3, 5, 7, 2, 9, 4]], dtype=np.int32)
NEW = 6
toks, logits_meta = {}, {}
for name, kw in (("pod_loc", dict(combine="locality")),
                 ("pod_xla", dict(combine="xla")),
                 ("data_loc", dict(combine="locality", seq_axes=("data",)))):
    eng = Engine(cfg, mesh, params, ServeSpec(batch=1, cache_len=32, **kw))
    if name == "pod_loc":
        assert eng.combine.algorithm == "locality", eng.combine
        assert eng.combine.p == 8 and eng.combine.p_local == 4, eng.combine
        assert eng.art.seq_axes == ("pod", "data"), eng.art.seq_axes
        assert eng.art.decode_fn_locality is not None, eng.art
    toks[name] = eng.generate(prompts, NEW)
for a in ("pod_xla", "data_loc"):
    assert np.array_equal(toks["pod_loc"], toks[a]), (a, toks)
out["decode"] = {"tokens_exact_equal": True, "steps": NEW,
                 "tokens": toks["pod_loc"].tolist()}
print("JSON" + json.dumps(out))
"""


MIGRATE_HLO_CODE = r"""
import json, dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.core.hlo_analysis import collective_stats
from repro.core.topology import device_pod_map
from repro.models import transformer
from repro.serve import Engine, Request, ServeSpec, StepClock
from repro.serve.scheduler import make_migrate_insert_fn

mesh = jax.make_mesh((2, 4), ("pod", "data"))
jax.set_mesh(mesh)
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2,
                          dtype=jnp.float32)
params = transformer.init_params(jax.random.PRNGKey(0), cfg)
pod_map = device_pod_map(mesh, ("pod",))
out = {"mesh": "2x4 (pod,data)", "n_devices": 8}

spec = ServeSpec(batch=8, cache_len=32, page_len=8, migrate="locality_bruck")
eng = Engine(cfg, mesh, params, spec, clock=StepClock())
sched = eng.scheduler
assert sched._migrate_fn is not None, "no migrate path on a 2-pod mesh?"

# --- HLO ground truth: explicit cache_migrate vs flat GSPMD reshard ------
# Both variants consume the SAME donor-layout input (a B=1 prefill cache,
# KV slabs sequence-sharded over ('pod','data')) and produce the same
# batch-sharded serving cache; the only difference is who moves the slab —
# the locality-Bruck allgather or GSPMD's implicit flat resharding.
a_cache = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                       sched.abstract_cache)
a_req = transformer.cache_specs(cfg, 1, spec.cache_len)
a_row = jax.ShapeDtypeStruct((), jnp.int32)
for name, alg in (("locality", "locality_bruck"), ("flat_xla", "gspmd")):
    fn = make_migrate_insert_fn(mesh, spec.batch, sched.cache_sh,
                                sched.donor_specs, sched.donor_sh, alg)
    hlo = fn.lower(a_cache, a_req, a_row).compile().as_text()
    st = collective_stats(hlo, pod_map)
    out[name] = {
        "counts": dict(st.counts),
        "permute_edges_nonlocal": st.permute_edges_nonlocal,
        "permute_bytes_nonlocal": st.permute_bytes_nonlocal,
        "group_msgs_nonlocal": st.group_msgs_nonlocal,
        "group_bytes_nonlocal": st.group_bytes_nonlocal,
        "nonlocal_msgs": st.nonlocal_msgs,
        "nonlocal_bytes": st.nonlocal_bytes,
    }

# --- runtime ledger: forced cross-pod migrations reconcile exactly -------
# 8 requests, every one homed in pod 0: four land in pod-0 rows (local
# insert), four spill into pod-1 rows — each spill is one cache migration
# the comm ledger must account exactly (predicted == actual, not approx)
rng = np.random.default_rng(0)
for i in range(8):
    eng.submit(Request(tokens=rng.integers(0, cfg.vocab_size, (6,),
                                           ).astype(np.int32),
                       max_new=4, home_pod=0, arrival_s=0.0))
res = eng.drain()
assert len(res) == 8, len(res)
assert all(r.finish_reason == "length" for r in res.values()), res
assert sched._migrations == 4, sched._migrations
assert sum(r.migrated for r in res.values()) == 4, res
comm = eng.scheduler.stats()["comm"]
mig = comm["serve/migrate:locality_bruck"]
assert mig["match"] is True, comm
assert mig["predicted_nonlocal_bytes"] > 0, comm      # crossed the DCN
assert all(rec["match"] for rec in comm.values()), comm
out["ledger"] = {k: {"match": bool(v["match"]),
                     "invocations": v["invocations"],
                     "nonlocal_bytes": v["predicted_nonlocal_bytes"]}
                 for k, v in comm.items()}
out["migrations"] = sched._migrations
print("JSON" + json.dumps(out))
"""


MOE_HLO_CODE = r"""
import json, dataclasses
import jax, numpy as np
from repro import configs
from repro.core.hlo_analysis import collective_stats, op_payloads
from repro.core.topology import device_pod_map
from repro.train.step import custom_batch_specs, make_train_step

out = {}
base = configs.get_smoke("qwen2-moe-a2.7b")
# E = p so the expert dimension shards exactly across the composite DP span;
# q=3 exercises the non-power partial-round geometry of the inter-pod phase
for key, (q, pl) in (("moe_2pod", (2, 8)), ("moe_3pod", (3, 8))):
    p = q * pl
    devs = np.asarray(jax.devices()[:p]).reshape(q, pl)
    mesh = jax.sharding.Mesh(devs, ("pod", "data"))
    jax.set_mesh(mesh)
    cfg = dataclasses.replace(base, n_layers=2, n_experts=p)
    bspec = custom_batch_specs(cfg, p, 32)
    pod_map = device_pod_map(mesh, ("pod",))
    cell = {"mesh": f"{q}x{pl} (pod,data)", "n_devices": p}
    for name, md in (("locality", "locality"), ("flat_xla", "xla")):
        art = make_train_step(cfg, mesh, grad_sync="locality", shape=bspec,
                              donate=False, moe_dispatch=md)
        assert art.moe_dispatch == md, art
        hlo = art.step_fn.lower(art.abstract_state, bspec).compile().as_text()
        st = collective_stats(hlo, pod_map)
        cell[name] = {
            "counts": dict(st.counts),
            "transport": art.moe_transport,
            "permute_edges_nonlocal": st.permute_edges_nonlocal,
            "permute_bytes_nonlocal": st.permute_bytes_nonlocal,
            "group_msgs_nonlocal": st.group_msgs_nonlocal,
            "group_bytes_nonlocal": st.group_bytes_nonlocal,
            "nonlocal_msgs": st.nonlocal_msgs,
            "nonlocal_bytes": st.nonlocal_bytes,
        }
        if name == "locality":
            # token transport must engage (q < top_k * capacity_factor) and
            # the whole step must lower without a single grouped all-to-all:
            # every exchange beyond the minimized inter-pod phase is a
            # collective-permute
            assert art.moe_transport == "tokens", art
            assert not op_payloads(hlo, "all-to-all"), \
                "grouped all-to-all survived in the locality dispatch"
        else:
            assert op_payloads(hlo, "all-to-all"), \
                "flat baseline lost its all-to-all"
    out[key] = cell
print("JSON" + json.dumps(out))
"""


def _reduction(cell: dict) -> dict:
    loc, flat = cell["locality"], cell["flat_xla"]
    return {
        "nonlocal_bytes_ratio": (loc["nonlocal_bytes"] / flat["nonlocal_bytes"]
                                 if flat["nonlocal_bytes"] else None),
        "nonlocal_msgs_ratio": (loc["nonlocal_msgs"] / flat["nonlocal_msgs"]
                                if flat["nonlocal_msgs"] else None),
    }


def main() -> list[tuple]:
    results = {}
    for key, code, devices in (("train_fsdp", TRAIN_HLO_CODE, 32),
                               ("serve_combine", SERVE_HLO_CODE, 512),
                               ("threepod", THREEPOD_HLO_CODE, 24),
                               ("cache_migrate", MIGRATE_HLO_CODE, 8),
                               ("moe", MOE_HLO_CODE, 24),
                               ("numerics", NUMERICS_CODE, 8),
                               ("numerics_3pod", NUMERICS3_CODE, 6)):
        stdout = run_multidevice(code, devices=devices, timeout=3000)
        line = [l for l in stdout.splitlines() if l.startswith("JSON")][0]
        results[key] = json.loads(line[4:])

    # the 3-pod subprocess emits both halves in one JSON — promote each to a
    # top-level cell so the gate below (and the trend plots) see four cells
    three = results.pop("threepod")
    for key in ("train_fsdp_3pod", "serve_combine_3pod"):
        results[key] = {"mesh": three["mesh"], "n_devices": three["n_devices"],
                        **three[key]}
    # same for the two moe-multipod cells (one subprocess, q=2 and q=3)
    moe = results.pop("moe")
    results.update(moe)

    rows = []
    for key in ("train_fsdp", "serve_combine",
                "train_fsdp_3pod", "serve_combine_3pod", "cache_migrate",
                "moe_2pod", "moe_3pod"):
        cell = results[key]
        loc, flat = cell["locality"], cell["flat_xla"]
        red = _reduction(cell)
        cell["reduction"] = red
        # the acceptance gate FIRST (before any ratio formatting — a flat
        # path with zero classified traffic must fail with the real
        # numbers, not a NoneType format error): the locality path must
        # move strictly fewer non-local bytes AND messages than the flat
        # XLA path, and its outer rounds must genuinely cross the DCN
        assert loc["nonlocal_bytes"] > 0 and loc["nonlocal_msgs"] > 0, cell
        assert loc["nonlocal_bytes"] < flat["nonlocal_bytes"], cell
        assert loc["nonlocal_msgs"] < flat["nonlocal_msgs"], cell
        assert loc["permute_edges_nonlocal"] > 0, cell
        # mirror the per-cell DCN ground truth into the metrics registry so
        # results/metrics.json carries it alongside the step telemetry
        from repro import telemetry
        reg = telemetry.get_registry()
        for path_, v in ((f"multipod/{key}/locality_nonlocal_bytes",
                          loc["nonlocal_bytes"]),
                         (f"multipod/{key}/flat_nonlocal_bytes",
                          flat["nonlocal_bytes"]),
                         (f"multipod/{key}/bytes_ratio",
                          red["nonlocal_bytes_ratio"]),
                         (f"multipod/{key}/msgs_ratio",
                          red["nonlocal_msgs_ratio"])):
            if v is not None:
                reg.gauge(path_).set(v)
        rows.append((
            f"multipod/{key}/nonlocal_bytes", None,
            f"locality={loc['nonlocal_bytes']:.0f} "
            f"flat={flat['nonlocal_bytes']:.0f} "
            f"ratio={red['nonlocal_bytes_ratio']:.4f}"))
        rows.append((
            f"multipod/{key}/nonlocal_msgs", None,
            f"locality={loc['nonlocal_msgs']:.0f} "
            f"flat={flat['nonlocal_msgs']:.0f} "
            f"ratio={red['nonlocal_msgs_ratio']:.4f}"))
    mig = results["cache_migrate"]
    assert all(rec["match"] for rec in mig["ledger"].values()), mig["ledger"]
    rows.append(("multipod/cache_migrate/ledger", None,
                 f"migrations={mig['migrations']} labels="
                 f"{len(mig['ledger'])} all_reconciled=True"))
    for nkey in ("numerics", "numerics_3pod"):
        num = results[nkey]
        assert num["train"]["loss_bitwise_equal"], num
        assert num["decode"]["tokens_exact_equal"], num
        rows.append((f"multipod/{nkey}/train", None,
                     f"loss_bitwise=True params_bitwise="
                     f"{num['train']['params_bitwise']} "
                     f"params_max_rel_diff="
                     f"{num['train']['params_max_rel_diff']:.2e}"))
        rows.append((f"multipod/{nkey}/decode", None,
                     f"tokens_exact=True steps={num['decode']['steps']}"))

    write_bench_json(OUT, results, devices=512)
    return emit(rows)


if __name__ == "__main__":
    main()
