"""Benchmark harness entry: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only fig7,...]``

Prints ``name,us_per_call,derived`` CSV rows per benchmark.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from . import (collective_hlo_audit, fig3_pingpong, fig7_model_scaling,
               fig8_model_datasize, fig9_measured, roofline)

BENCHES = {
    "fig3": fig3_pingpong,
    "fig7": fig7_model_scaling,
    "fig8": fig8_model_datasize,
    "fig9": fig9_measured,
    "hlo_audit": collective_hlo_audit,
    "roofline": roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(BENCHES))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            BENCHES[name].main()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED benchmarks: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
