"""Benchmark harness entry: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [bench] [--only fig7,...]``
    Prints ``name,us_per_call,derived`` CSV rows per benchmark.

``python benchmarks/run.py tune [--p 16 --p-local 4 ...]``
    Runs the repro.tuning sweep: measures (or, on CPU containers,
    deterministically simulates) every collective algorithm across message
    sizes, persists ``results/tuning_table.json`` — which
    ``allgather(..., algorithm="auto")`` and ``grad_sync="auto"`` then
    resolve through — and writes the Fig. 9-style measured-vs-modeled
    report to ``BENCH_tuning.json``.

``python benchmarks/run.py overlap``
    Eager vs double-buffered-prefetch FSDP train pipeline (DESIGN.md §5):
    wall-clock step time / tokens per second on an 8-device subprocess plus
    the simulated exposed-communication split; writes
    ``BENCH_overlap.json`` and fails if the prefetched pipeline does not
    reduce exposed communication (or breaks exact equality).

``python benchmarks/run.py serve_traffic``
    Continuous batching vs lockstep waves under Poisson and bursty request
    traces on a deterministic virtual clock (DESIGN.md §9): per-request
    p50/p99 latency and SLO goodput of the Scheduler vs the wave baseline;
    writes ``BENCH_serve_traffic.json`` and fails unless continuous
    batching wins p99 latency AND SLO goodput on every trace.

``python benchmarks/run.py multipod``
    The ('pod','data') sharding-domain proof (DESIGN.md §6): compiled-HLO
    non-local byte/message comparison of the locality train-FSDP and
    serve-combine paths vs the flat XLA paths on multi-pod meshes, plus
    layout-equivalence numerics; writes ``BENCH_multipod.json`` and fails
    unless the locality paths move strictly fewer inter-pod bytes AND
    messages.

``python benchmarks/run.py fleet``
    Fleet-controller chaos mini-soak (DESIGN.md §11): seeded kills,
    preemptions and stragglers on a 12-device pod-aligned run; trends the
    controller's decision latency and failure-to-resumed recovery
    wall-clock; writes ``BENCH_fleet.json`` and fails unless the run
    converges to healthy.
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback

if __package__ in (None, ""):                     # `python benchmarks/run.py`
    _REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _REPO)
    sys.path.insert(1, os.path.join(_REPO, "src"))
    __package__ = "benchmarks"

from . import (checkpoint_bench, collective_hlo_audit, fig3_pingpong,
               fig7_model_scaling, fig8_model_datasize, fig9_measured,
               overlap, roofline, serve_combine)

BENCHES = {
    "checkpoint": checkpoint_bench,
    "fig3": fig3_pingpong,
    "fig7": fig7_model_scaling,
    "fig8": fig8_model_datasize,
    "fig9": fig9_measured,
    "hlo_audit": collective_hlo_audit,
    "overlap": overlap,
    "roofline": roofline,
    "serve_combine": serve_combine,
}


def run_benches(only: str | None) -> None:
    from repro import telemetry
    from .common import telemetry_artifacts
    names = only.split(",") if only else list(BENCHES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            with telemetry.span(f"bench/{name}"):
                BENCHES[name].main()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    telemetry_artifacts("bench")
    if failed:
        print(f"FAILED benchmarks: {failed}", file=sys.stderr)
        sys.exit(1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd")
    bench = sub.add_parser("bench", help="run the figure benchmarks (default)")
    bench.add_argument("--only", default=None,
                       help="comma-separated subset of " + ",".join(BENCHES))
    sub.add_parser("tune", help="run the collective tuning sweep",
                   add_help=False)
    sub.add_parser("overlap", help="eager vs prefetched pipeline benchmark")
    sub.add_parser("multipod", help="('pod','data') non-local traffic proof")
    sub.add_parser("serve_traffic",
                   help="continuous batching vs lockstep waves")
    sub.add_parser("fleet", help="fleet-controller chaos mini-soak")
    # default to `bench` for backward compatibility: `run.py --only fig7`
    argv = sys.argv[1:]
    if argv[:1] == ["tune"]:
        from repro.tuning import sweep
        sweep.main(argv[1:])
        return
    if argv[:1] == ["overlap"]:
        from repro import telemetry
        from .common import telemetry_artifacts
        print("name,us_per_call,derived")
        try:
            with telemetry.span("bench/overlap"):
                overlap.main()
        finally:                   # keep artifacts from failed gate runs
            telemetry_artifacts("overlap")
        return
    if argv[:1] == ["serve_traffic"]:
        from repro import telemetry
        from . import serve_traffic
        from .common import telemetry_artifacts
        print("name,us_per_call,derived")
        try:
            with telemetry.span("bench/serve_traffic"):
                serve_traffic.main()
        finally:                   # keep artifacts from failed gate runs
            telemetry_artifacts("serve_traffic")
        return
    if argv[:1] == ["multipod"]:
        from repro import telemetry
        from . import multipod
        from .common import telemetry_artifacts
        print("name,us_per_call,derived")
        try:
            with telemetry.span("bench/multipod"):
                multipod.main()
        finally:                   # keep artifacts from failed gate runs
            telemetry_artifacts("multipod")
        return
    if argv[:1] == ["fleet"]:
        from repro import telemetry
        from . import fleet_bench
        from .common import telemetry_artifacts
        print("name,us_per_call,derived")
        try:
            with telemetry.span("bench/fleet"):
                fleet_bench.main()
        finally:                   # keep artifacts from failed gate runs
            telemetry_artifacts("fleet", devices=fleet_bench.DEVICES)
        return
    if argv[:1] != ["bench"] and any(a.startswith("--only") for a in argv):
        argv = ["bench"] + argv
    args = ap.parse_args(argv or ["bench"])
    run_benches(getattr(args, "only", None))


if __name__ == "__main__":
    main()
