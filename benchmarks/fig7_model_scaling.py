"""Paper Fig. 7: modeled standard vs locality-aware Bruck across node counts
and processes-per-node (PPN), Lassen parameters, 4-byte payload per rank."""
from __future__ import annotations

from repro.core import cost_model as CM
from repro.core.topology import ceil_log

from .common import emit


def main() -> list[tuple]:
    rows = []
    block = 4.0
    for ppn in (4, 8, 16, 32):
        for nodes in (16, 64, 256, 1024, 4096):
            p = nodes * ppn
            std = CM.bruck_model(p, block, CM.LASSEN) * 1e6
            loc = CM.locality_bruck_model(p, ppn, block, CM.LASSEN) * 1e6
            rows.append((f"fig7/ppn{ppn}_nodes{nodes}_bruck", round(std, 3),
                         f"nonlocal_msgs={ceil_log(2, p)}"))
            rows.append((f"fig7/ppn{ppn}_nodes{nodes}_locality", round(loc, 3),
                         f"nonlocal_msgs={ceil_log(ppn, nodes)} "
                         f"speedup={std / loc:.2f}x"))
    # paper claim: improvements amplified with more processes per region
    gain = {ppn: (CM.bruck_model(1024 * ppn, block, CM.LASSEN) /
                  CM.locality_bruck_model(1024 * ppn, ppn, block, CM.LASSEN))
            for ppn in (4, 32)}
    assert gain[32] > gain[4], "gain must grow with PPN"
    return emit(rows)


if __name__ == "__main__":
    main()
