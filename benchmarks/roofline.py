"""§Roofline aggregation: read results/dryrun/*.json into the per-cell table.

Run the dry-run sweep first (python -m repro.launch.dryrun --all --mesh
single/multi). Emits one row per (arch × shape × mesh) with the three
roofline terms, the dominant bottleneck, and the useful-FLOPs ratio; also
writes results/roofline_table.md for EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os

from repro.core.hlo_analysis import Roofline

from .common import RESULTS, emit

DRYRUN = os.path.join(RESULTS, "dryrun")


def load_cells(tag: str | None = None) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        want_tag = tag or ""
        if r.get("tag", "") != want_tag:
            continue
        if r["status"] == "ok":
            # recompute the roofline row from raw fields (keeps older JSONs
            # consistent with the current term definitions)
            roof = Roofline(
                flops=float(r["cost"].get("flops", 0.0)),
                hbm_bytes=float(r["cost"].get("bytes accessed", 0.0)),
                collective_bytes=float(r["roofline"]["collective_bytes"]),
                n_chips=r["n_chips"], model_flops=r["model_flops"])
            r["roofline"] = roof.row()
        cells.append(r)
    return cells


def table_md(cells: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | useful | roofline |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in cells:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                         f"| — | skipped ({r['reason']}) | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                         f"| — | ERROR | — | — |")
            continue
        f = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {f['compute_s']:.2e} | {f['memory_s']:.2e} "
            f"| {f['collective_s']:.2e} | {f['dominant']} "
            f"| {f['useful_fraction']:.2f} | {f['roofline_fraction']:.3f} |")
    return hdr + "\n".join(lines) + "\n"


def main() -> list[tuple]:
    cells = load_cells()
    rows = []
    for r in cells:
        if r["status"] != "ok":
            rows.append((f"roofline/{r['arch']}_{r['shape']}_{r['mesh']}",
                         None, r["status"]))
            continue
        f = r["roofline"]
        step_s = max(f["compute_s"], f["memory_s"], f["collective_s"])
        rows.append((f"roofline/{r['arch']}_{r['shape']}_{r['mesh']}",
                     round(step_s * 1e6, 1),
                     f"dom={f['dominant']} frac={f['roofline_fraction']:.3f}"))
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "roofline_table.md"), "w") as f:
        f.write(table_md(cells))
    return emit(rows)


if __name__ == "__main__":
    main()
