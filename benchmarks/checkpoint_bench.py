"""checkpoint: distributed save/restore wall-clock (DESIGN.md §10).

Spawns an 8-device ('pod','data') subprocess with an FSDP-style sharded
pytree (each device holds 1/8 of every matrix leaf), and times the v2
store's three paths:

* ``save_wall_s``      — sharded save (per-chunk npy + sha256 + replicas +
  atomic commit); per-process traffic is the *shard* bytes, never the
  assembled leaves (``max_chunk_bytes`` asserts it);
* ``restore_wall_s``   — same-layout restore (chunk-exact reload);
* ``reshard_wall_s``   — restore onto the flat 8-device layout (every
  device's slice assembled from intersecting chunks).

Byte accounting (``save_bytes``, ``replica_bytes``, ``max_chunk_bytes``)
and the postal-model replication estimate ride along so the trend gate
sees layout drift, not just runner noise. Writes ``BENCH_checkpoint.json``.
"""
from __future__ import annotations

import json
import os

from .common import REPO, emit, run_multidevice, write_bench_json

OUT = os.path.join(REPO, "BENCH_checkpoint.json")

DEVICES = 8

CODE = r"""
import json, shutil, tempfile, time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import telemetry
from repro.checkpoint import restore_checkpoint, save_checkpoint

mesh = jax.make_mesh((2, 4), ("pod", "data"))
jax.set_mesh(mesh)
sh = NamedSharding(mesh, P(("pod", "data")))
rep = NamedSharding(mesh, P())

keys = jax.random.split(jax.random.PRNGKey(0), 8)
tree = {f"w{i}": jax.device_put(
            jax.random.normal(keys[i], (1024, 256), jnp.float32), sh)
        for i in range(6)}
tree["scale"] = jax.device_put(jnp.ones((256,), jnp.float32), rep)
tree["step"] = jnp.asarray(0, jnp.int32)

ckdir = tempfile.mkdtemp()
ITERS = 5
t0 = time.perf_counter()
for it in range(ITERS):
    save_checkpoint(ckdir, it, tree, keep_last=2)
save_s = (time.perf_counter() - t0) / ITERS

like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
shardings = jax.tree.map(lambda x: x.sharding, tree)
t0 = time.perf_counter()
for _ in range(ITERS):
    step, out = restore_checkpoint(ckdir, like, shardings=shardings)
restore_s = (time.perf_counter() - t0) / ITERS
assert step == ITERS - 1, step

flat = jax.make_mesh((1, 8), ("pod", "data"))
fsh = jax.tree.map(
    lambda x: NamedSharding(flat, P(("pod", "data")) if x.ndim == 2
                            else P()), tree)
t0 = time.perf_counter()
for _ in range(ITERS):
    step, out2 = restore_checkpoint(ckdir, like, shardings=fsh)
reshard_s = (time.perf_counter() - t0) / ITERS
for k in tree:
    assert np.array_equal(np.asarray(out[k]), np.asarray(out2[k])), k

g = telemetry.get_registry().snapshot()["gauges"]
full_leaf = 1024 * 256 * 4
assert g["checkpoint/max_chunk_bytes"] == full_leaf // 8, g
shutil.rmtree(ckdir)
print("RESULT " + json.dumps({
    "save_wall_s": save_s, "restore_wall_s": restore_s,
    "reshard_wall_s": reshard_s,
    "save_bytes": g["checkpoint/save_bytes"],
    "replica_bytes": g["checkpoint/replica_bytes"],
    "max_chunk_bytes": g["checkpoint/max_chunk_bytes"],
    "replication": g["checkpoint/replication"],
    "replication_model_s": g.get("checkpoint/replication_model_s", 0.0),
}))
"""


def main() -> None:
    out = run_multidevice(CODE, DEVICES)
    line = [l for l in out.splitlines() if l.startswith("RESULT ")][-1]
    r = json.loads(line[len("RESULT "):])
    emit([("ckpt_save", r["save_wall_s"] * 1e6, "sharded save, 8 dev"),
          ("ckpt_restore", r["restore_wall_s"] * 1e6, "same-layout restore"),
          ("ckpt_reshard", r["reshard_wall_s"] * 1e6,
           "(2,4)->flat(8) reshard restore")])
    write_bench_json(OUT, {"checkpoint": r}, devices=DEVICES)


if __name__ == "__main__":
    import sys
    if __package__ in (None, ""):
        _REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        sys.path.insert(0, _REPO)
        sys.path.insert(1, os.path.join(_REPO, "src"))
        __package__ = "benchmarks"
    main()
