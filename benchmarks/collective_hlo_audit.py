"""The paper's claim, verified on the compiled artifact (production mesh):

lower each allgather algorithm over the multi-pod mesh (2 pods × 256), scan
the HLO, and count collective-permute edges/bytes crossing the pod boundary.
The locality-aware Bruck must cross with ≤ ceil(log_pl(r)) messages per
chip and ~b/p_ℓ bytes, vs log2(p) messages / (p-1)/p·b bytes for standard
Bruck — this is the TPU-native analogue of the paper's Figs. 9-10.
"""
from __future__ import annotations

import json
import os

from .common import RESULTS, emit, run_multidevice

CODE = r"""
import jax, jax.numpy as jnp, json
from jax.sharding import PartitionSpec as P
from repro.core import collectives as C
from repro.core.hlo_analysis import collective_stats
from repro.core.topology import device_pod_map
from repro.launch.mesh import make_production_mesh

mesh = make_production_mesh(multi_pod=True)      # (2,16,16)
pod_map = device_pod_map(mesh, ("pod",))
x = jnp.ones((512, 256), jnp.float32)            # 1 KiB per chip

out = {}
for alg in ["xla", "bruck", "ring", "multilane", "locality_bruck"]:
    def body(s, a=alg):
        return C.allgather(s, ("pod",), ("data", "model"), algorithm=a,
                           tiled=True)
    f = jax.jit(jax.shard_map(body, mesh=mesh,
                              in_specs=P(("pod", "data", "model")),
                              out_specs=P(("pod", "data", "model"))))
    hlo = f.lower(x).compile().as_text()
    st = collective_stats(hlo, pod_map)
    out[alg] = {
        "edges_local": st.permute_edges_local,
        "edges_nonlocal": st.permute_edges_nonlocal,
        "counts": dict(st.counts),
        "bytes": dict(st.bytes_),
    }
print("JSON" + json.dumps(out))
"""


def main() -> list[tuple]:
    cache = os.path.join(RESULTS, "hlo_audit.json")
    if os.path.exists(cache):
        with open(cache) as f:
            out = json.load(f)
    else:
        stdout = run_multidevice(CODE, devices=512, timeout=2400)
        line = [l for l in stdout.splitlines() if l.startswith("JSON")][0]
        out = json.loads(line[4:])
        os.makedirs(RESULTS, exist_ok=True)
        with open(cache, "w") as f:
            json.dump(out, f, indent=1)

    rows = []
    for alg, st in out.items():
        # per-chip non-local messages = nonlocal edges / 512 chips
        nl_msgs = st["edges_nonlocal"] / 512
        rows.append((f"hlo_audit/{alg}_nonlocal_edges", None,
                     f"edges={st['edges_nonlocal']} per_chip={nl_msgs:.1f} "
                     f"local_edges={st['edges_local']}"))
    if "bruck" in out and "locality_bruck" in out:
        assert (out["locality_bruck"]["edges_nonlocal"]
                < out["bruck"]["edges_nonlocal"]), \
            "locality-aware must cross the pod boundary less"
    return emit(rows)


if __name__ == "__main__":
    main()
