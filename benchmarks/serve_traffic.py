"""serve-traffic: continuous batching vs lockstep waves under a request trace.

Spawns an 8-device ('pod','data') subprocess, drives the Scheduler with a
deterministic ``StepClock`` over two arrival traces — Poisson and bursty —
and compares it against the wave baseline (collect whatever has arrived,
run one lockstep ``generate`` to the longest decode budget in the wave,
repeat). Requests carry heterogeneous decode budgets, so the wave baseline
suffers the two classic lockstep pathologies the continuous engine was
built to remove: late arrivals wait out the whole wave, and short requests
are head-of-line blocked behind the longest request in their wave.

Both sides run the same model on the same mesh under the same virtual
pricing (one tick per decode step, ``PREFILL_COST`` per prefill — the wave
gets its prefill batched for free at the same flat cost). Latencies are
exact functions of the trace and the schedule, not of CI-runner noise.

Reports per-request p50/p99 latency (ticks), makespan, and SLO goodput
(tokens from requests finishing within ``SLO_FACTOR`` x their own no-queue
latency, per tick). Wall seconds come from one measured conversion factor
(median decode-step wall time) applied to the virtual makespan. Writes
``BENCH_serve_traffic.json`` and fails unless continuous batching beats
the wave baseline on p99 latency AND SLO goodput on every trace.
"""
from __future__ import annotations

import json
import os
import sys

if __package__ in (None, ""):          # `python benchmarks/serve_traffic.py`
    _REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _REPO)
    sys.path.insert(1, os.path.join(_REPO, "src"))
    __package__ = "benchmarks"

from .common import REPO, emit, run_multidevice, write_bench_json

OUT = os.path.join(REPO, "BENCH_serve_traffic.json")

DEVICES = 8

CODE = r"""
import json, time, warnings
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro import configs
from repro.models import transformer
from repro.serve import Engine, Request, ServeSpec, StepClock

B, S, CL, PAGE = 8, 6, 32, 8
PREFILL_COST = 0.5      # vs 1.0 per decode step
SLO_FACTOR = 3.0        # SLO = 3x the request's own no-queue latency
N_REQ = 24

mesh = jax.make_mesh((2, 4), ("pod", "data"))
jax.set_mesh(mesh)
cfg = dataclasses.replace(configs.get_smoke("llama3.2-3b"), n_layers=2)
params = transformer.init_params(jax.random.PRNGKey(0), cfg)
spec = ServeSpec(batch=B, cache_len=CL, page_len=PAGE)

rng = np.random.default_rng(0)
PROMPTS = rng.integers(0, cfg.vocab_size, (N_REQ, S), dtype=np.int32)
# heterogeneous decode budgets: the wave baseline locksteps every request
# to the longest budget in its wave
MAX_NEW = rng.integers(4, 17, N_REQ)


def trace_poisson(rng, mean_gap=2.0):
    # staggered single arrivals: the regime continuous batching exists for
    gaps = rng.exponential(mean_gap, N_REQ)
    return np.cumsum(gaps) - gaps[0]


def trace_bursty(rng, group=12, gap=24.0):
    # bursts larger than the batch: the tail of each burst spills into a
    # second wave while continuous admission backfills rows as they free
    return np.asarray([gap * (i // group) for i in range(N_REQ)])


def run_continuous(arrivals):
    clock = StepClock(decode_cost=1.0, prefill_cost=PREFILL_COST)
    eng = Engine(cfg, mesh, params, spec, clock=clock)
    rid_of = {}
    for i in range(N_REQ):
        rid_of[eng.submit(Request(tokens=PROMPTS[i],
                                  max_new=int(MAX_NEW[i]),
                                  home_pod=i % 2,
                                  arrival_s=float(arrivals[i])))] = i
    t0 = time.perf_counter()
    results = eng.drain()
    wall = time.perf_counter() - t0
    st = eng.scheduler.stats()
    lat = np.zeros(N_REQ)
    for rid, r in results.items():
        lat[rid_of[rid]] = r.finished_s - r.arrival_s
    assert all(r.finish_reason == "length" for r in results.values())
    return lat, clock.t, wall / max(st["steps"], 1), st


def run_wave(arrivals):
    # the lockstep baseline: at each wave start, take whatever has arrived
    # (up to B), prefill once (batched, flat PREFILL_COST — generous), then
    # decode max(max_new in wave) lockstep steps; late arrivals wait out
    # the whole wave and short requests wait for the longest.
    eng = Engine(cfg, mesh, params, spec)
    order = np.argsort(arrivals, kind="stable")
    pending = [(int(i), float(arrivals[i])) for i in order]
    t, lat = 0.0, np.zeros(N_REQ)
    while pending:
        t = max(t, pending[0][1])
        wave = [iv for iv in pending if iv[1] <= t][:B]
        pending = [iv for iv in pending if iv not in wave]
        steps = max(int(MAX_NEW[i]) for i, _ in wave)
        prompts = np.zeros((B, S), np.int32)
        for row, (i, _) in enumerate(wave):
            prompts[row] = PROMPTS[i]
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            eng.generate(prompts, steps)
        t += PREFILL_COST + steps
        for i, arr in wave:
            lat[i] = t - arr
    return lat, t


def summarize(lat, makespan):
    slo = SLO_FACTOR * (PREFILL_COST + MAX_NEW)   # per-request SLO
    within = lat <= slo
    return {
        "p50_latency_ticks": float(np.percentile(lat, 50)),
        "p99_latency_ticks": float(np.percentile(lat, 99)),
        "mean_latency_ticks": float(lat.mean()),
        "makespan_ticks": float(makespan),
        "requests_in_slo": int(within.sum()),
        "slo_goodput_tokens_per_tick":
            float(MAX_NEW[within].sum() / makespan),
    }


out = {"n_requests": N_REQ, "batch": B, "slo_factor": SLO_FACTOR,
       "prefill_cost": PREFILL_COST, "total_tokens": int(MAX_NEW.sum()),
       "traces": {}}
step_s = None
for name, arrivals in (("poisson", trace_poisson(np.random.default_rng(1))),
                       ("bursty", trace_bursty(np.random.default_rng(2)))):
    c_lat, c_make, c_step_s, c_stats = run_continuous(arrivals)
    w_lat, w_make = run_wave(arrivals)
    step_s = c_step_s if step_s is None else min(step_s, c_step_s)
    cell = {"continuous": summarize(c_lat, c_make),
            "wave": summarize(w_lat, w_make)}
    cell["continuous"]["migrations"] = c_stats["migrations"]
    cell["continuous"]["decode_steps"] = c_stats["steps"]
    # ledger: every stamped comm label must reconcile vs its compiled HLO
    comm = c_stats.get("comm", {})
    cell["continuous"]["comm_labels_matched"] = sum(
        1 for rec in comm.values() if rec.get("match"))
    assert all(rec.get("match") for rec in comm.values()), comm
    out["traces"][name] = cell

# the measured wall conversion: virtual ticks -> seconds via the decode-step
# wall time of the continuous runs
out["decode_step_s"] = step_s
for name, cell in out["traces"].items():
    for side in ("continuous", "wave"):
        mk = cell[side]["makespan_ticks"]
        cell[side]["tokens_per_s"] = float(MAX_NEW.sum() / (mk * step_s))

print("TRAFFIC_OK" + json.dumps(out))
"""


def main() -> None:
    stdout = run_multidevice(CODE, DEVICES, timeout=2400)
    marker = "TRAFFIC_OK"
    line = next(ln for ln in stdout.splitlines() if ln.startswith(marker))
    res = json.loads(line[len(marker):])

    rows = []
    gates = {}
    for name, cell in res["traces"].items():
        cont, wave = cell["continuous"], cell["wave"]
        gates[name] = {
            "p99_improves": cont["p99_latency_ticks"] < wave["p99_latency_ticks"],
            "slo_goodput_improves":
                cont["slo_goodput_tokens_per_tick"]
                > wave["slo_goodput_tokens_per_tick"],
        }
        rows.append((f"serve_traffic/{name}/continuous_p99", None,
                     f"{cont['p99_latency_ticks']:.1f} ticks"))
        rows.append((f"serve_traffic/{name}/wave_p99", None,
                     f"{wave['p99_latency_ticks']:.1f} ticks"))
        rows.append((f"serve_traffic/{name}/slo_goodput", None,
                     f"{cont['slo_goodput_tokens_per_tick']:.3f} vs "
                     f"{wave['slo_goodput_tokens_per_tick']:.3f} tok/tick"))
    res["gates"] = gates
    write_bench_json(OUT, res, devices=DEVICES)
    emit(rows)

    for name, g in gates.items():
        assert g["p99_improves"], (
            f"continuous batching lost on p99 latency for the {name} trace: "
            f"{res['traces'][name]}")
        assert g["slo_goodput_improves"], (
            f"continuous batching lost on SLO goodput for the {name} trace: "
            f"{res['traces'][name]}")
    print(f"serve_traffic: gates passed for {list(gates)} -> {OUT}")


if __name__ == "__main__":
    main()
